"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgl_prox_ref(beta, step, w, tau, lam):
    """Two-level prox, grouped layout (G, ng); step/w are (G,)."""
    t1 = tau * lam * step[:, None]
    z = jnp.sign(beta) * jnp.maximum(jnp.abs(beta) - t1, 0.0)
    nrm = jnp.linalg.norm(z, axis=1, keepdims=True)
    t2 = (1.0 - tau) * lam * (w * step)[:, None]
    scale = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0)
    return scale * z


def dual_norm_ref(x, alpha, R):
    """Exact sorted-prefix-sum Lambda per group (paper Algorithm 1)."""
    from repro.core.epsilon_norm import lam as lam_exact

    return lam_exact(x, alpha, R)


def screening_scores_ref(Xt, theta, tau):
    corr = Xt @ theta
    st = jnp.maximum(jnp.abs(corr) - tau, 0.0)
    return corr, st * st


def bcd_epochs_ref(Xt, Lg, w, fmask, beta, resid, tau, lam_b, n_epochs):
    """Batched cyclic-BCD oracle: a per-lambda ``lax.scan`` over groups.

    The per-group update is line-for-line
    :func:`repro.core.solver.bcd_epochs` (the solver's XLA path), applied
    independently per lambda b — the fused kernel must match this
    BIT-exactly in f64 interpret mode.  ``Xt (Gb, n, ng)``, ``Lg``/``w``
    ``(Gb,)``, ``fmask``/``beta`` ``(B, Gb, ng)``, ``resid (B, n)``,
    ``lam_b (B,)``.
    """
    live = (Lg > 0).astype(beta.dtype)
    safe_L = jnp.where(Lg > 0, Lg, 1.0)

    def one_lambda(bb, rr, fm, lam_):
        step = lam_ / safe_L
        thr1 = tau * step
        thr2 = (1.0 - tau) * w * step

        def group_update(resid, inputs):
            Xg, bg, L, t1, t2, m, lv = inputs
            grad_step = (Xg.T @ resid) / L
            z = (bg + grad_step) * m
            z = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t1, 0.0)
            nrm = jnp.linalg.norm(z)
            z = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * z
            new_bg = jnp.where(lv > 0, z, bg)
            resid = resid + Xg @ (bg - new_bg)
            return resid, new_bg

        def epoch(carry, _):
            bb, rr = carry
            rr, bb = jax.lax.scan(
                group_update, rr, (Xt, bb, safe_L, thr1, thr2, fm, live)
            )
            return (bb, rr), None

        (bb, rr), _ = jax.lax.scan(epoch, (bb, rr), None, length=n_epochs)
        return bb, rr

    outs = [one_lambda(beta[b], resid[b], fmask[b], lam_b[b])
            for b in range(beta.shape[0])]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def bcd_epochs_logistic_ref(Xt, Lg, w, fmask, beta, z, y, tau, lam_b,
                            n_epochs):
    """Batched majorized-BCD oracle for the logistic mega-kernel.

    The per-group update is line-for-line
    :func:`repro.core.solver.bcd_epochs_loss` with ``LogisticLoss``
    (majorization bound ``Lg / 4``, fresh ``rho = y - sigmoid(z)`` per
    group, rank-one linear-predictor update), applied independently per
    lambda — the fused logistic kernel must match BIT-exactly in f64
    interpret mode.  ``z (B, n)`` is the linear predictor carry.
    """
    live = (Lg > 0).astype(beta.dtype)
    Lmaj = 0.25 * Lg
    safe_L = jnp.where(Lg > 0, Lmaj, 1.0)

    def one_lambda(bb, zz, fm, lam_):
        step = lam_ / safe_L
        thr1 = tau * step
        thr2 = (1.0 - tau) * w * step

        def group_update(z, inputs):
            Xg, bg, L, t1, t2, m, lv = inputs
            rho = y - jax.nn.sigmoid(z)
            grad_step = (Xg.T @ rho) / L
            u = (bg + grad_step) * m
            u = jnp.sign(u) * jnp.maximum(jnp.abs(u) - t1, 0.0)
            nrm = jnp.linalg.norm(u)
            u = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0) * u
            new_bg = jnp.where(lv > 0, u, bg)
            z = z + Xg @ (new_bg - bg)
            return z, new_bg

        def epoch(carry, _):
            bb, zz = carry
            zz, bb = jax.lax.scan(
                group_update, zz, (Xt, bb, safe_L, thr1, thr2, fm, live)
            )
            return (bb, zz), None

        (bb, zz), _ = jax.lax.scan(epoch, (bb, zz), None, length=n_epochs)
        return bb, zz

    outs = [one_lambda(beta[b], z[b], fmask[b], lam_b[b])
            for b in range(beta.shape[0])]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))
