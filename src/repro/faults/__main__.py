"""``python -m repro.faults`` — run the seeded chaos matrix.

The chaos CI job runs ``--check --json BENCH_pr9.json`` and fails the
build on any scenario failure, any unsafe certificate, or any hung
future.
"""
from __future__ import annotations

import argparse
import sys

from .chaos import SCENARIOS, run_matrix, write_report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.faults",
        description="Seeded fault-injection matrix (the executable spec "
                    "of the degradation protocol).")
    ap.add_argument("--seed", type=int, default=0,
                    help="FaultPlan seed (bit-flip offsets etc.)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every scenario passes with "
                         "0 unsafe certificates and 0 hung futures")
    ap.add_argument("--only", nargs="*", metavar="NAME",
                    help="run only the named scenarios")
    ap.add_argument("--list", action="store_true",
                    help="list scenario names and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, _fn in SCENARIOS:
            print(name)
        return 0

    print(f"chaos matrix: {len(args.only or SCENARIOS)} scenarios, "
          f"seed={args.seed}")
    report = run_matrix(seed=args.seed, names=args.only)
    if args.json:
        write_report(report, args.json)
        print(f"report -> {args.json}")
    print(f"{len(report['scenarios'])} scenarios, "
          f"{report['failures']} failures, "
          f"{report['unsafe_certificates']} unsafe certificates, "
          f"{report['hung_futures']} hung futures "
          f"({report['seconds']:.1f}s)")
    if args.check and not report["ok"]:
        print("CHAOS CHECK FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
