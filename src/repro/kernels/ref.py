"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgl_prox_ref(beta, step, w, tau, lam):
    """Two-level prox, grouped layout (G, ng); step/w are (G,)."""
    t1 = tau * lam * step[:, None]
    z = jnp.sign(beta) * jnp.maximum(jnp.abs(beta) - t1, 0.0)
    nrm = jnp.linalg.norm(z, axis=1, keepdims=True)
    t2 = (1.0 - tau) * lam * (w * step)[:, None]
    scale = jnp.maximum(1.0 - t2 / jnp.maximum(nrm, 1e-30), 0.0)
    return scale * z


def dual_norm_ref(x, alpha, R):
    """Exact sorted-prefix-sum Lambda per group (paper Algorithm 1)."""
    from repro.core.epsilon_norm import lam as lam_exact

    return lam_exact(x, alpha, R)


def screening_scores_ref(Xt, theta, tau):
    corr = Xt @ theta
    st = jnp.maximum(jnp.abs(corr) - tau, 0.0)
    return corr, st * st
