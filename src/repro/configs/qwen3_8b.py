"""qwen3-8b — dense GQA with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4_096,
    n_heads=32,
    n_kv=8,
    d_ff=12_288,
    vocab=151_936,
    head_dim=128,
    qk_norm=True,
    subquadratic=False,
    notes="qk_norm, GQA kv=8",
)
