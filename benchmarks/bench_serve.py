"""Synthetic multi-tenant load generator for the serving layer.

Plays a two-wave, >= 8-tenant workload against :class:`repro.serve.
SGLServer` and against a no-coalescing / no-cache / no-store baseline
(same server machinery with every optimisation disabled), measuring
requests/sec and p50/p99 request latency:

* wave 1 — four tenants submit the identical problem+grid (coalesce
  into ONE solve) and three more submit a second problem (their own
  coalesced solve);
* wave 2 — a repeat tenant (exact-store hit), a perturbed-``y`` tenant
  on a tail sub-grid (warm-started from the stored path, shared
  transposed design), and a refined-grid tenant on the first problem
  (session-cache hit + warm start).

Both modes get one untimed warmup pass first so the process-global XLA
jit caches are equally warm when the timed passes run — the comparison
measures the serving layer (queue collapse, cached sessions, the store),
not who happened to compile first.

Correctness is asserted inline, not trusted: coalesced betas must be
bit-identical to a solo ``session.solve_path`` run, the coalesced solves
must actually engage the batched-lambda machinery, the repeat tenant
must hit the caches, and every warm-started response is checked for
unsafe certificate reuse against a tight-tolerance unscreened reference
(any group a warm path screened must be zero there).  ``--smoke`` runs
the same workload at CI scale; ``--json`` records the perf trajectory
(``BENCH_pr7.json``).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from benchmarks.common import emit, header, write_json

from repro import ckpt
from repro.core import sgl
from repro.core.session import SGLSession, SolverConfig, lambda_grid
from repro.data.synthetic import make_synthetic
from repro.faults import FaultPlan, FaultSpec, inject
from repro.obs import trace as obs_trace
from repro.obs.export import merge_bench, percentile
from repro.serve import PathRequest, ServeConfig, SGLServer


def _problem(seed: int, n: int, p: int, groups: int, tau: float):
    X, y, _beta, sizes = make_synthetic(
        n=n, p=p, n_groups=groups, gamma1=3, gamma2=3, seed=seed
    )
    return sgl.make_problem(X, y, sizes, tau=tau), X, y, sizes


def _build_workload(n, p, groups, T, tau, solver):
    """The >= 8-tenant request list (returns requests + reference info)."""
    prob1, X1, y1, sizes = _problem(11, n, p, groups, tau)
    prob2, _X2, _y2, _s2 = _problem(13, n, p, groups, tau)
    grid1 = lambda_grid(float(sgl.lambda_max(prob1)), T=T, delta=0.5)
    grid2 = lambda_grid(float(sgl.lambda_max(prob2)), T=T, delta=0.5)
    # Perturbed-y re-solve on the warm tail of the grid (the serving
    # pattern stored paths accelerate: the path starts mid-grid, far
    # from the trivial lambda_max cold start).
    rng = np.random.default_rng(7)
    y_pert = y1 + 0.02 * rng.standard_normal(y1.shape)
    prob1p = sgl.make_problem(X1, y_pert, sizes, tau=tau)
    tail = grid1[T // 2:]
    # Refined-grid re-solve: a denser tail for the same problem.
    refined = lambda_grid(float(sgl.lambda_max(prob1)), T=2 * T,
                          delta=0.5)[T:]

    wave1 = [
        PathRequest("tenant-a1", prob1, grid1),
        PathRequest("tenant-a2", prob1, grid1),
        PathRequest("tenant-a3", prob1, grid1),
        PathRequest("tenant-a4", prob1, grid1),
        PathRequest("tenant-b1", prob2, grid2),
        PathRequest("tenant-b2", prob2, grid2),
        PathRequest("tenant-b3", prob2, grid2),
    ]
    wave2 = [
        PathRequest("tenant-a5", prob1, grid1),          # exact repeat
        PathRequest("tenant-p1", prob1p, tail),          # perturbed y
        PathRequest("tenant-r1", prob1, refined),        # refined grid
    ]
    return wave1, wave2, dict(prob1=prob1, grid1=grid1, prob1p=prob1p,
                              tail=tail, refined=refined)


def _play(server: SGLServer, waves) -> tuple[list, float]:
    """Submit the waves pipelined — wave ``k+1`` goes in as soon as the
    FIRST response of wave ``k`` lands, so later arrivals overlap with
    in-flight service (the load shape a queue actually sees, and what
    makes queue depth visible in the latency percentiles).  Returns
    (responses, total_seconds) with per-request latency stamped via
    done-callbacks on each future."""
    latencies = {}
    all_futs = []
    trigger = None
    t0 = time.perf_counter()
    for wave in waves:
        if trigger is not None:
            trigger.result(timeout=3600)
        futs = []
        for req in wave:
            t_sub = time.perf_counter()
            fut = server.submit(req)
            fut.add_done_callback(
                lambda f, t=t_sub: latencies.setdefault(
                    id(f), time.perf_counter() - t))
            futs.append(fut)
        all_futs.extend(futs)
        trigger = futs[0]
    responses = [(fut.result(timeout=3600), latencies[id(fut)])
                 for fut in all_futs]
    return responses, time.perf_counter() - t0


def _emit_latencies(case: str, responses, total_s: float) -> None:
    lat = [t for _resp, t in responses]
    emit("serve", case, "requests", len(lat))
    emit("serve", case, "total_seconds", total_s)
    emit("serve", case, "requests_per_sec", len(lat) / total_s)
    emit("serve", case, "latency_p50_s", percentile(lat, 50))
    emit("serve", case, "latency_p99_s", percentile(lat, 99))


def _unsafe_cert_reuse(resp, problem, grid, base_cfg: SolverConfig) -> int:
    """Screened-but-nonzero count vs a tight-tol unscreened reference —
    any hit means a stale certificate leaked through a warm start."""
    ref = SGLSession(problem, SolverConfig(
        tol=1e-9, max_epochs=10 * base_cfg.max_epochs, rule="none",
    )).solve_path(np.asarray(grid))
    viol = 0
    for t in range(len(grid)):
        screened = ~np.asarray(resp.result.group_active[t])
        nz = np.linalg.norm(np.asarray(ref.betas[t]), axis=-1) > 1e-8
        viol += int((screened & nz).sum())
    return viol


def _serve_cfg(solver: SolverConfig) -> ServeConfig:
    return ServeConfig(default_solver=solver, coalesce_window_s=0.05,
                       batch_lambdas=4)


def _baseline_cfg(solver: SolverConfig) -> ServeConfig:
    # Same server machinery with every optimisation disabled: no
    # coalescing window, every request a fresh session, nothing stored.
    return ServeConfig(default_solver=solver, coalesce=False,
                       warm_start=False, serve_from_store=False,
                       session_capacity=0, store_capacity=0,
                       batch_lambdas=4, coalesce_window_s=0.0)


def run(n=64, p=512, groups=64, T=10, tau=0.3, tol=1e-7,
        max_epochs=20_000, obs_json=None) -> None:
    solver = SolverConfig(tol=tol, max_epochs=max_epochs,
                          full_round_every=10 ** 9,
                          solver_backend="pallas")
    wave1, wave2, refs = _build_workload(n, p, groups, T, tau, solver)

    # ---- untimed warmup: compile every program either mode uses (XLA
    # jit caches are process-global; server state is not shared) ----
    for cfg in (_serve_cfg(solver), _baseline_cfg(solver)):
        warm_srv = SGLServer(cfg).start()
        _play(warm_srv, [wave1, wave2])
        warm_srv.stop()

    # ---- serve mode: coalescing + session cache + certificate store ----
    # Traced: the obs span taxonomy yields the per-stage latency
    # breakdown (request/coalesce/store/cache/warm_eval/path/...) the
    # BENCH artifact records next to the end-to-end percentiles.
    obs_trace.configure(enabled=True, sample_every=1)
    obs_trace.TRACER.reset()
    server = SGLServer(_serve_cfg(solver)).start()
    responses, total_serve = _play(server, [wave1, wave2])
    server.stop()
    stages = obs_trace.TRACER.stage_summary()
    obs_trace.configure(enabled=False)
    queue_wait = server.metrics.histogram("serve.queue_wait_s").summary()
    _emit_latencies("serve", responses, total_serve)
    for stage, s in sorted(stages.items()):
        emit("serve_stages", stage, "count", s["n"])
        emit("serve_stages", stage, "p50_s", s["p50"] or 0.0)
        emit("serve_stages", stage, "p99_s", s["p99"] or 0.0)
    stats = server.stats()
    by_tenant = {r.tenant: r for r, _t in responses}

    # ---- correctness audits (assert, then emit) ----
    # 1. coalescing engaged across >= 2 tenants, through the
    #    batched-lambda machinery (dense warm grid + Pallas backend).
    coal = [r for r, _t in responses if r.coalesced_n >= 2]
    coal_tenants = {r.tenant for r in coal}
    assert len(coal_tenants) >= 2, "coalescing never engaged"
    batched = max(r.result.batched_lambdas for r in coal)
    assert batched > 0, "coalesced solves never batched lambdas"
    # 2. solo-vs-coalesced bit parity (fresh solo session, same config).
    solo = SGLSession(refs["prob1"], solver).solve_path(
        refs["grid1"], batch_lambdas=4)
    np.testing.assert_array_equal(
        by_tenant["tenant-a1"].result.betas, solo.betas,
        err_msg="coalesced betas differ from solo solve_path")
    # 3. repeat tenant hits the store; refined-grid tenant hits the
    #    session cache.
    assert by_tenant["tenant-a5"].store_hit, "exact repeat missed store"
    np.testing.assert_array_equal(
        by_tenant["tenant-a5"].result.betas, solo.betas)
    assert stats["cache"]["hits"] > 0, "session cache never hit"
    assert stats["cache"]["retraces"] == 0, "cached session retraced"
    # 4. warm starts engaged, and no stale certificate was reported safe.
    warm = [r for r, _t in responses if r.warm_started]
    assert warm, "no warm-started response in the workload"
    unsafe = 0
    unsafe += _unsafe_cert_reuse(by_tenant["tenant-p1"], refs["prob1p"],
                                 refs["tail"], solver)
    unsafe += _unsafe_cert_reuse(by_tenant["tenant-r1"], refs["prob1"],
                                 refs["refined"], solver)
    assert unsafe == 0, f"unsafe certificate reuse: {unsafe} groups"
    assert all(r.result.certificates_safe for r, _t in responses)

    emit("serve", "audit", "coalesced_requests",
         stats["coalesced_requests"])
    emit("serve", "audit", "coalesced_tenants", len(coal_tenants))
    emit("serve", "audit", "batched_lambdas", batched)
    emit("serve", "audit", "path_solves", stats["path_solves"])
    emit("serve", "audit", "store_served", stats["store_served"])
    emit("serve", "audit", "warm_started", stats["warm_started"])
    emit("serve", "audit", "cache_hits", stats["cache"]["hits"])
    emit("serve", "audit", "cache_hit_rate",
         stats["cache"]["hits"]
         / max(stats["cache"]["hits"] + stats["cache"]["misses"], 1))
    emit("serve", "audit", "design_cache_hits",
         stats["cache"]["design_hits"])
    emit("serve", "audit", "retraces", stats["cache"]["retraces"])
    emit("serve", "audit", "unsafe_cert_reuse", unsafe)

    # ---- baseline: same machinery, every optimisation off ----
    baseline = SGLServer(_baseline_cfg(solver)).start()
    responses_b, total_base = _play(baseline, [wave1, wave2])
    baseline.stop()
    _emit_latencies("baseline", responses_b, total_base)

    rps_serve = len(responses) / total_serve
    rps_base = len(responses_b) / total_base
    lat_serve = [t for _r, t in responses]
    lat_base = [t for _r, t in responses_b]
    p50_serve = percentile(lat_serve, 50)
    p50_base = percentile(lat_base, 50)
    emit("serve", "speedup", "requests_per_sec_ratio",
         rps_serve / rps_base)
    emit("serve", "speedup", "latency_p50_ratio", p50_base / p50_serve)
    assert rps_serve > rps_base, (
        f"serving did not beat the baseline on requests/sec "
        f"({rps_serve:.3f} vs {rps_base:.3f})")
    assert p50_serve < p50_base, (
        f"serving did not beat the baseline on p50 latency "
        f"({p50_serve:.3f}s vs {p50_base:.3f}s)")
    if obs_json:
        merge_bench(obs_json, "serve", {
            "workload": {"tenants": len(wave1) + len(wave2), "n": n,
                         "p": p, "groups": groups, "T": T},
            "latency_s": {"p50": p50_serve,
                          "p99": percentile(lat_serve, 99),
                          "n": len(lat_serve),
                          "total": float(total_serve)},
            "baseline_latency_s": {"p50": p50_base,
                                   "p99": percentile(lat_base, 99),
                                   "n": len(lat_base),
                                   "total": float(total_base)},
            "requests_per_sec": rps_serve,
            "baseline_requests_per_sec": rps_base,
            "speedup_rps": rps_serve / rps_base,
            "stages": stages,
            "queue_wait_s": queue_wait,
            "counters": {k: int(v) for k, v in server.counters.items()},
            "cache": stats["cache"],
        })
    print("SERVE BENCH PASS")


# ---------------------------------------------------------------------------
# --faults mode: the same 10-tenant load under injected failures
# ---------------------------------------------------------------------------

def _lat_stats(responses, total_s: float) -> dict:
    lat = [t for _r, t in responses]
    return {
        "requests": int(len(lat)),
        "total_seconds": float(total_s),
        "latency_p50_s": percentile(lat, 50),
        "latency_p99_s": percentile(lat, 99),
    }


def _merge_json(path: str, key: str, payload: dict) -> None:
    """Merge ``payload`` under ``key`` — the chaos runner records into
    the same file (``"chaos"``), and CI order must not matter."""
    try:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) or "rows" in data:
            data = {}
    except (FileNotFoundError, json.JSONDecodeError):
        data = {}
    data[key] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"faults report -> {path}")


def run_faults(n=48, p=256, groups=32, T=8, tau=0.3, tol=1e-7,
               max_epochs=20_000, json_path=None) -> None:
    """10-tenant two-wave load with a mid-wave worker kill, a mid-path
    segment kill, and one poisoned (truncated) checkpoint.

    Availability must stay 1.0 — every future resolves with a result
    whose betas are bit-identical to the fault-free pass; the cost of
    the faults shows up only in p99 (which includes recovery) and the
    retry/restart/quarantine counters recorded alongside the fault-free
    baseline.
    """
    solver = SolverConfig(tol=tol, max_epochs=max_epochs)
    wave1, wave2, _refs = _build_workload(n, p, groups, T, tau, solver)
    waves = [wave1, wave2]
    n_req = len(wave1) + len(wave2)

    def chunk_cfg(tmpdir):
        return ServeConfig(default_solver=solver, coalesce_window_s=0.05,
                           batch_lambdas=4, ckpt_dir=tmpdir,
                           ckpt_every=max(T // 2, 2),
                           retry_backoff_s=0.01)

    # Untimed warmup so both timed passes run against warm jit caches.
    with tempfile.TemporaryDirectory() as tmp:
        warm = SGLServer(chunk_cfg(tmp)).start()
        _play(warm, waves)
        warm.stop()

    # ---- fault-free pass (the recovery-cost baseline) ----
    with tempfile.TemporaryDirectory() as tmp:
        server = SGLServer(chunk_cfg(tmp)).start()
        responses, total_ff = _play(server, waves)
        server.stop()
    base_stats = _lat_stats(responses, total_ff)
    base_stats["availability"] = 1.0
    base_by_tenant = {r.tenant: r for r, _t in responses}

    # ---- faulted pass: kill the worker as the SECOND coalesced group
    # enters service (mid wave 1), kill it again mid-path on a later
    # segment, and truncate one published checkpoint so the recovery
    # resume has to quarantine it ----
    plan = FaultPlan((
        FaultSpec("serve.worker", "kill", hits=(1,)),
        FaultSpec("serve.segment", "kill", hits=(3,)),
        FaultSpec("ckpt.payload", "truncate", hits=(2,)),
    ))
    q0 = ckpt.quarantine_count()
    with tempfile.TemporaryDirectory() as tmp:
        server = SGLServer(chunk_cfg(tmp)).start()
        with inject(plan) as log:
            responses_f, total_f = _play(server, waves)
        server.stop()
    fired = log.count()
    resolved = [r for r, _t in responses_f if r is not None]
    availability = len(resolved) / n_req
    fault_stats = _lat_stats(responses_f, total_f)
    fault_stats.update({
        "availability": float(availability),
        "faults_fired": int(fired),
        "retries": int(server.counters["retries"]),
        "worker_restarts": int(server.counters["worker_restarts"]),
        "checkpoints_quarantined": int(ckpt.quarantine_count() - q0),
    })

    # ---- the contract: nothing lost, nothing wrong, only slower ----
    assert fired >= 3, f"only {fired} faults fired"
    assert availability == 1.0, f"availability {availability:.2f} < 1.0"
    assert server.counters["worker_restarts"] >= 2
    assert server.counters["retries"] >= 2
    for r, _t in responses_f:
        np.testing.assert_array_equal(
            r.result.betas, base_by_tenant[r.tenant].result.betas,
            err_msg=f"{r.tenant}: faulted betas differ from fault-free")
    assert all(r.result.certificates_safe for r, _t in responses_f)

    for case, st in (("fault_free", base_stats), ("faulted", fault_stats)):
        for metric, value in st.items():
            emit("serve_faults", case, metric, value)
    if json_path:
        _merge_json(json_path, "serve_faults", {
            "workload": {"tenants": n_req, "n": n, "p": p,
                         "groups": groups, "T": T},
            "fault_free": base_stats,
            "faulted": fault_stats,
        })
    print("SERVE FAULTS BENCH PASS")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="CI scale: small shapes, same assertions")
    parser.add_argument("--faults", action="store_true",
                        help="run the fault-injection load (mid-wave "
                             "worker kill + poisoned checkpoint) and "
                             "record availability/p99/retries")
    parser.add_argument("--json", metavar="PATH",
                        help="write the emitted rows as JSON (the "
                             "BENCH_pr7.json perf-trajectory record; "
                             "with --faults, merged into BENCH_pr9-style "
                             "fault reports)")
    parser.add_argument("--obs-json", metavar="PATH", default=None,
                        help="merge the serve section (end-to-end "
                             "percentiles + per-stage span breakdown + "
                             "queue-wait histogram) into a "
                             "repro.obs.bench/v1 file (BENCH_pr10.json)")
    args = parser.parse_args()
    header()
    if args.faults:
        if args.smoke:
            run_faults(n=32, p=128, groups=16, T=6, json_path=args.json)
        else:
            run_faults(json_path=args.json)
        return
    # T=10 at delta=0.5 is the densest-grid recipe that keeps the warm
    # predictor satisfied on these shapes, so the coalesced solves
    # exercise the batched-lambda machinery (same recipe as bench_path).
    if args.smoke:
        run(n=64, p=512, groups=64, T=10, obs_json=args.obs_json)
    else:
        run(n=64, p=512, groups=64, T=14, obs_json=args.obs_json)
    if args.json:
        write_json(args.json, extra={"bench": "serve",
                                     "smoke": bool(args.smoke)})


if __name__ == "__main__":
    main()
