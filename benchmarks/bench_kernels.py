"""Pallas kernel parity + dispatch-path timing.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers measure the jnp fallback / dispatch overhead only; the
correctness deltas against ``ref.py`` are the meaningful output (the TPU
timing story lives in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, timeit


def main(G=512, ng=16, n=256, tau=0.3) -> None:
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    beta = jax.random.normal(k1, (G, ng), jnp.float32)
    step = jnp.abs(jax.random.normal(k2, (G,), jnp.float32)) + 0.1
    w = jnp.sqrt(jnp.full((G,), float(ng), jnp.float32))
    Xt = jax.random.normal(k3, (G * ng, n), jnp.float32)  # (p, n) layout
    theta = jax.random.normal(k4, (n,), jnp.float32)
    lam = 0.7

    # fused two-level prox
    out = ops.sgl_prox(beta, step, w, tau=tau, lam=lam)
    want = ref.sgl_prox_ref(beta, step, w, tau, lam)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("kernels", f"sgl_prox_G{G}", "max_err", err)
    emit("kernels", f"sgl_prox_G{G}", "us_per_call",
         1e6 * timeit(lambda: ops.sgl_prox(beta, step, w, tau=tau, lam=lam)))

    # fused screening scores
    sc = ops.screening_scores(Xt, theta, tau=tau)
    sc_ref = ref.screening_scores_ref(Xt, theta, tau)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(sc, sc_ref))
    emit("kernels", f"screening_G{G}", "max_err", err)
    emit("kernels", f"screening_G{G}", "us_per_call",
         1e6 * timeit(lambda: ops.screening_scores(Xt, theta, tau=tau)))

    # grouped dual-norm bisection
    x = jax.random.normal(k1, (G, ng), jnp.float32)
    alpha = jnp.full((G,), 0.6, jnp.float32)
    R = jnp.full((G,), 0.8, jnp.float32)
    nu = ops.dual_norm_groups(x, alpha, R)
    nu_ref = jax.vmap(ref.dual_norm_ref)(x, alpha, R)
    err = float(jnp.max(jnp.abs(nu - nu_ref)))
    emit("kernels", f"dual_norm_G{G}", "max_err", err)
    emit("kernels", f"dual_norm_G{G}", "us_per_call",
         1e6 * timeit(lambda: ops.dual_norm_groups(x, alpha, R)))


if __name__ == "__main__":
    from .common import header

    header()
    main()
