"""Batched LM serving smoke: prefill a batch of prompts, then greedily
decode token-by-token against the KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch demo --tokens 32

Uses the reduced config on CPU.  The ``prefill`` / ``decode_step`` pair
exercised here is the same one ``launch/dryrun.py`` lowers for the
256/512-chip meshes (the ``decode_32k`` shape: one token against a 32k
cache at batch 128).  For serving the *sparse-group lasso path solver*
— request coalescing, session caching, warm-start certificate store —
see ``repro.serve`` and ``examples/serve_sgl.py``.
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get(args.arch).reduced()
    api = build(cfg)
    params = api.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(2, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32,
    )
    max_seq = args.prompt_len + args.tokens

    # prefill: one pass over the prompts, builds the KV cache
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: api.prefill(
        p, t, cache_len=max_seq, dtype=jnp.float32))
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"arch={args.arch} (reduced): prefill {args.batch}x"
          f"{args.prompt_len} tokens in {t_prefill * 1e3:.1f} ms")

    # greedy decode loop against the cache
    decode = jax.jit(api.decode_step)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)

    per_tok = dt / max(args.tokens - 1, 1) * 1e3
    print(f"decoded {args.tokens} tokens/seq x {args.batch} seqs: "
          f"{per_tok:.2f} ms/token (batch)")
    print(f"sample continuation (seq 0): {gen[0][:16].tolist()}")
    assert np.isfinite(per_tok)
    assert gen.shape == (args.batch, args.tokens)
    print("serve smoke OK")


if __name__ == "__main__":
    main()
