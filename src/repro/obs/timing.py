"""Measured kernel timing for every registered ``LaunchSpec`` kernel.

The dry-run roofline (:mod:`repro.launch.roofline`) predicts time from HLO
costs without ever running anything; this harness produces the matching
*measured* term.  Discipline, per the accelerator timing guide:

1. jit-warm: call each dispatch wrapper ``warmup`` times and
   ``jax.block_until_ready`` the result, so compile/trace time never
   pollutes a sample;
2. time ``repeat`` calls individually, each fenced by
   ``block_until_ready`` (JAX dispatch is asynchronous — un-fenced
   wall-clock measures the host, not the kernel);
3. report the median (robust) and the min (best-case) and feed the median
   to :func:`repro.launch.roofline.achieved_vs_peak`.

Each timed case mirrors one ``register_kernel_audit`` entry from
:mod:`repro.kernels.ops` — same kernel family, same dispatch wrapper the
solver uses.  ``scale="smoke"`` shrinks the geometry so interpret-mode CPU
(where Pallas executes the grid in Python) stays fast enough for CI;
``scale="paper"`` uses the registered audit shapes and is the setting that
matters on a real accelerator.  On CPU the numbers are an interpret-mode
dispatch story, not a speed story — ``interpret=True`` is stamped into
every row so BENCH readers can tell.

Flops/bytes are hand-written model formulas per kernel (documented inline);
``LaunchSpec.io_bytes`` (unique-bytes lower bound) is the fallback.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import numpy as np

from ..kernels import ops as kops
from ..kernels._util import on_tpu
from ..launch.roofline import achieved_vs_peak
from . import metrics as obs_metrics

_M_MEASURED = obs_metrics.REGISTRY.histogram(
    "kernels.measured_wall_s",
    help="Median measured kernel wall-clock per timing-harness case "
         "(jit-warm + block_until_ready)")


class TimingCase(NamedTuple):
    """One timed kernel: thunk builder + flops/bytes model.

    ``build(scale)`` returns ``(fn, args, flops, bytes)`` — ``fn(*args)``
    is exactly the dispatch wrapper the solver calls.
    """

    audit_name: str
    build: Callable[[str], Tuple[Callable, tuple, float, float]]


def _rng():
    return np.random.default_rng(0)


def _f64(a):
    return jax.numpy.asarray(np.asarray(a, dtype=np.float64))


def _corr_shape(scale: str) -> Tuple[int, int]:
    return (512, 256) if scale == "smoke" else (4096, 1024)


def _build_corr(scale: str):
    p, n = _corr_shape(scale)
    r = _rng()
    Xt = _f64(r.standard_normal((p, n)))
    theta = _f64(r.standard_normal(n))
    # matvec: 2 flops per (p, n) cell; traffic: design + vector + result
    flops = 2.0 * p * n
    bts = 8.0 * (p * n + n + p)
    return kops.screening_corr, (Xt, theta), flops, bts


def _build_scores(scale: str):
    p, n = _corr_shape(scale)
    r = _rng()
    Xt = _f64(r.standard_normal((p, n)))
    theta = _f64(r.standard_normal(n))
    fn = lambda Xt, th: kops.screening_scores(Xt, th, 0.3)  # noqa: E731
    # corr matvec + fused soft-threshold square (~4 flops/row)
    flops = 2.0 * p * n + 4.0 * p
    bts = 8.0 * (p * n + n + 2 * p)
    return fn, (Xt, theta), flops, bts


def _build_dual_norm(scale: str):
    G = 512 if scale == "smoke" else 4096
    ng, n_iter = 8, 64
    r = _rng()
    x = _f64(r.standard_normal((G, ng)))
    alpha = _f64(np.full(G, 0.7))
    R = _f64(np.full(G, 0.3))
    fn = lambda x, a, R: kops.dual_norm_groups(x, a, R, n_iter=n_iter)  # noqa: E731
    # bisection: ~4 flops per feature per iteration (shrink, square, sum)
    flops = 4.0 * G * ng * n_iter
    bts = 8.0 * (G * ng + 3 * G)
    return fn, (x, alpha, R), flops, bts


def _build_prox(scale: str):
    G = 512 if scale == "smoke" else 4096
    ng = 8
    r = _rng()
    beta = _f64(r.standard_normal((G, ng)))
    step = _f64(np.full(G, 0.05))
    w = _f64(np.ones(G))
    fn = lambda b, s, w: kops.sgl_prox(b, s, w, 0.3, 1.0)  # noqa: E731
    # two-level prox: ~6 flops per feature (shrink + norm + group scale)
    flops = 6.0 * G * ng
    bts = 8.0 * (2 * G * ng + 2 * G)
    return fn, (beta, step, w), flops, bts


def _bcd_geom(scale: str, bucket: bool):
    if scale == "smoke":
        return (2 if bucket else 1), 16, 128, (16 if bucket else 8), 2
    return ((4, 256, 1024, 16, 3) if bucket else (1, 64, 2048, 8, 2))


def _bcd_inputs(B, Gb, n, ng):
    r = _rng()
    Xt = _f64(r.standard_normal((Gb, n, ng)))
    Lg = _f64(np.sum(np.asarray(Xt) ** 2, axis=(1, 2)) / ng + 1.0)
    w = _f64(np.ones(Gb))
    fmask = _f64(np.ones((B, Gb, ng)))
    beta = _f64(0.01 * r.standard_normal((B, Gb, ng)))
    lam_b = _f64(np.full(B, 0.1))
    return Xt, Lg, w, fmask, beta, lam_b


def _build_bcd(scale: str, bucket: bool):
    B, Gb, n, ng, E = _bcd_geom(scale, bucket)
    Xt, Lg, w, fmask, beta, lam_b = _bcd_inputs(B, Gb, n, ng)
    resid = _f64(_rng().standard_normal((B, n)))
    fn = lambda *a: kops.bcd_epochs_fused(*a, n_epochs=E, block_g=8)  # noqa: E731
    args = (Xt, Lg, w, fmask, beta, resid, 0.3, lam_b)
    # per epoch, group: corr (2·n·ng) + residual rank-1 update (2·n·ng)
    flops = 4.0 * E * B * Gb * n * ng
    # design streamed once per epoch; state read+written once
    bts = 8.0 * (E * Gb * n * ng + 2 * (B * Gb * ng + B * n))
    return fn, args, flops, bts


def _build_bcd_logistic(scale: str):
    B, Gb, n, ng, E = _bcd_geom(scale, bucket=True)
    Xt, Lg, w, fmask, beta, lam_b = _bcd_inputs(B, Gb, n, ng)
    r = _rng()
    z = _f64(0.1 * r.standard_normal((B, n)))
    y = _f64((r.standard_normal(n) > 0).astype(np.float64))
    fn = lambda *a: kops.bcd_epochs_logistic_fused(  # noqa: E731
        *a, n_epochs=E, block_g=8)
    args = (Xt, Lg, w, fmask, beta, z, y, 0.3, lam_b)
    # lsq-epoch work + sigmoid/gradient on the carry (~8 flops per sample)
    flops = 4.0 * E * B * Gb * n * ng + 8.0 * E * B * Gb * n
    bts = 8.0 * (E * Gb * n * ng + 2 * (B * Gb * ng + B * n) + n)
    return fn, args, flops, bts


#: One timed case per registered kernel-audit family (names match
#: repro.kernels.ops register_kernel_audit entries).
CASES: Tuple[TimingCase, ...] = (
    TimingCase("bcd_epoch/bucket", lambda s: _build_bcd(s, bucket=True)),
    TimingCase("bcd_epoch/paper-ng8", lambda s: _build_bcd(s, bucket=False)),
    TimingCase("bcd_epoch_logistic/bucket", _build_bcd_logistic),
    TimingCase("screening_scores/default", _build_scores),
    TimingCase("screening_corr/default", _build_corr),
    TimingCase("dual_norm/paper-ng8", _build_dual_norm),
    TimingCase("sgl_prox/paper-ng8", _build_prox),
)


def measure_one(fn: Callable, args: tuple, warmup: int = 2,
                repeat: int = 5,
                clock: Callable[[], float] = time.perf_counter) -> dict:
    """Warm + fenced timing of one callable; median/min over ``repeat``."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(1, repeat)):
        t0 = clock()
        jax.block_until_ready(fn(*args))
        samples.append(clock() - t0)
    return {"median_s": statistics.median(samples), "min_s": min(samples),
            "samples": samples}


def measure_kernels(scale: str = "smoke", warmup: int = 2, repeat: int = 5,
                    names: Optional[Tuple[str, ...]] = None) -> Dict[str, dict]:
    """Run the harness over every (or the named) registered kernel case.

    Returns per-kernel rows ready for the BENCH ``kernels`` section:
    measured wall-clock, model flops/bytes, the audited LaunchSpec's VMEM
    footprint, and the ``achieved_vs_peak`` roofline column.
    """
    from ..analysis.registry import kernel_audits

    audits = kernel_audits()
    out: Dict[str, dict] = {}
    for case in CASES:
        if names is not None and case.audit_name not in names:
            continue
        fn, args, flops, bts = case.build(scale)
        t = measure_one(fn, args, warmup=warmup, repeat=repeat)
        _M_MEASURED.observe(t["median_s"])
        row = {
            "scale": scale,
            "interpret": not on_tpu(),
            "measured_s": t["median_s"],
            "min_s": t["min_s"],
            "model_flops": flops,
            "model_bytes": bts,
            "achieved": achieved_vs_peak(flops, bts, t["median_s"]),
        }
        builder = audits.get(case.audit_name)
        if builder is not None:
            spec = builder()
            row["vmem_bytes"] = spec.vmem_bytes
            row["audit_io_bytes"] = spec.io_bytes
        out[case.audit_name] = row
    return out
