"""mixtral-8x7b — MoE 8 experts top-2 with sliding-window attention.
[arXiv:2401.04088; hf]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv=8,
    d_ff=14_336,
    vocab=32_000,
    window=4_096,               # SWA -> rolling KV cache, subquadratic
    moe=MoEConfig(n_experts=8, top_k=2),
    subquadratic=True,
    notes="8 experts top-2, sliding-window attention (rolling cache)",
)
