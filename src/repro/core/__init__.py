"""Core library: the paper's contribution (GAP safe screening for SGL)."""
from .precision import ensure_x64

# Certificates are only certificates in f64 — enforce the posture before
# any submodule can build an array (see repro.core.precision).
ensure_x64()

from .epsilon_norm import (  # noqa: E402
    epsilon_decomposition,
    epsilon_norm,
    epsilon_norm_dual,
    lam,
    lam_bisect,
)
from .sgl import (
    SGLProblem,
    dual,
    flatten,
    unflatten,
    dual_scale,
    duality_gap,
    group_soft_threshold,
    lambda_max,
    make_problem,
    primal,
    problem_from_grouped,
    sgl_dual_norm,
    sgl_dual_norm_terms,
    sgl_norm,
    sgl_prox,
    soft_threshold,
)
from .screening import (
    ScreenResult,
    Sphere,
    dst3_sphere,
    dynamic_sphere,
    gap_sphere,
    screen,
    screened_dual_bound,
    screened_group_rate,
    sequential_sphere,
    static_sphere,
)
from .solver import (
    RoundResult,
    SolveCaches,
    SolveResult,
    bcd_epochs,
    resolve_screen_backend,
    screen_round,
    solve,
)
from .session import SGLSession, SolverConfig
from .elastic import make_elastic_problem, elastic_objective
from .path import PathResult, lambda_grid, solve_path
# NOTE: the unsafe StrongSequentialRule is deliberately NOT re-exported
# here — the solver layer only ever sees the ScreeningRule protocol
# (enforced by the CS002 lint in repro.analysis.cert_lint); import it
# from repro.rules where its heuristic nature is documented.
from ..rules import (
    GapSafeRule,
    ScreeningRule,
    StaticSafeRule,
    DynamicSafeRule,
    Dst3Rule,
    NoScreening,
    available_rules,
    get_rule,
    register_rule,
    resolve_rule,
)

__all__ = [
    "ensure_x64",
    "SGLProblem", "make_problem", "problem_from_grouped",
    "SGLSession", "SolverConfig",
    "solve", "solve_path", "lambda_grid",
    "lambda_max", "dual_scale", "duality_gap", "primal", "dual",
    "sgl_norm", "sgl_dual_norm", "sgl_dual_norm_terms", "sgl_prox",
    "soft_threshold", "screened_dual_bound", "screened_group_rate",
    "group_soft_threshold", "epsilon_norm", "epsilon_norm_dual",
    "epsilon_decomposition", "lam", "lam_bisect",
    "Sphere", "ScreenResult", "gap_sphere", "sequential_sphere",
    "static_sphere", "dynamic_sphere", "dst3_sphere", "screen",
    "SolveResult", "SolveCaches", "RoundResult", "PathResult",
    "bcd_epochs", "screen_round", "resolve_screen_backend",
    "make_elastic_problem", "elastic_objective", "flatten", "unflatten",
    "ScreeningRule", "GapSafeRule", "StaticSafeRule", "DynamicSafeRule",
    "Dst3Rule", "NoScreening",
    "available_rules", "get_rule", "register_rule", "resolve_rule",
]
