"""Tier-1 tests for the static-analysis gate (repro.analysis).

Two obligations, both load-bearing:

1. the repo itself passes every pass clean (the CI gate's contract), and
2. each lint demonstrably FIRES on the committed seeded-violation
   fixtures (tests/analysis_fixtures/ + inline bad specs) — a gate that
   cannot fail is not a gate.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import cert_lint, jaxpr_lints, pallas_audit
from repro.analysis.entrypoints import (
    EntryPointSpec,
    default_entry_specs,
    pairing_findings,
)
from repro.analysis.findings import Finding, summarize, to_payload
from repro.analysis.main import run_checks
from repro.kernels._util import ArraySpec, LaunchSpec
from repro.kernels import ops as kops

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")


def codes(findings, severity="error"):
    return sorted(f.code for f in findings if f.severity == severity)


# ---------------------------------------------------------------------------
# 1. The repo passes clean (the actual gate)
# ---------------------------------------------------------------------------

def test_repo_cert_pass_clean():
    assert codes(cert_lint.run()) == []


def test_repo_pallas_pass_clean():
    assert codes(pallas_audit.run()) == []


def test_repo_full_gate_clean():
    """The complete CI gate — cert + pallas + jaxpr incl. the retrace
    harness — holds on the repository itself."""
    payload = run_checks()
    assert payload["ok"], [f for f in payload["findings"]
                           if f["severity"] == "error"]
    assert set(payload["passes"]) == {"cert", "pallas", "jaxpr"}


def test_traceables_and_templates_pair_exactly():
    assert [str(f) for f in pairing_findings()] == []
    # and an empty template set flags every registered traceable (RG001)
    orphaned = pairing_findings(specs=[])
    assert orphaned and all(f.code == "RG001" for f in orphaned)
    # ... as does a template pointing at nothing
    ghost = EntryPointSpec(name="ghost", traceable="no_such_traceable",
                           build=lambda: None)
    assert any(f.code == "RG001" and "no_such_traceable" in f.message
               for f in pairing_findings(specs=[*default_entry_specs(),
                                                ghost]))


# ---------------------------------------------------------------------------
# 2. Cert lints fire on the seeded fixtures
# ---------------------------------------------------------------------------

def test_cs001_fires_on_forged_and_omitted_safety():
    fs = cert_lint.lint_result_constructions(
        os.path.join(FIXTURES, "bad_src"))
    assert codes(fs) == ["CS001"] * 4
    locs = sorted(f.location for f in fs)
    assert all(loc.startswith("results.py:") for loc in locs)
    msgs = " | ".join(f.message for f in fs)
    assert "safe=True" in msgs            # forged keyword
    assert "positional" in msgs.lower() or "position" in msgs
    assert "omits" in msgs                # omission = silent claim
    assert "certificates_safe" in msgs    # PathResult variant


def test_cs001_allowlist_accepts_rules_library():
    # the same literal inside the allow-file is not a finding
    fs = cert_lint.lint_result_constructions(
        os.path.join(FIXTURES, "bad_src"),
        allow_literal_files=("results.py",))
    # forged literals become allowed; the two *omission* findings remain
    assert codes(fs) == ["CS001"] * 2
    assert all("omits" in f.message for f in fs if f.severity == "error")


def test_cs002_fires_on_core_naming_strong_rule():
    fs = cert_lint.lint_strong_imports(os.path.join(FIXTURES, "bad_src"))
    assert fs and all(f.code == "CS002" for f in fs)
    assert any("core" in f.location for f in fs)


def test_cs003_fires_on_uncovered_safe_rule():
    fs = cert_lint.lint_safety_matrix(
        os.path.join(FIXTURES, "bad_tests"), ["gap", "static", "dynamic"])
    assert codes(fs) == ["CS003"]
    assert "'dynamic'" in fs[0].message


def test_cs003_fires_when_matrix_is_missing(tmp_path):
    fs = cert_lint.lint_safety_matrix(str(tmp_path), ["gap"])
    assert codes(fs) == ["CS003"]


def test_cs004_fires_on_exception_path_results_and_masks():
    fs = cert_lint.lint_exception_paths(os.path.join(FIXTURES, "bad_src"))
    assert codes(fs) == ["CS004"] * 4
    assert all(f.location.startswith(os.path.join("core", "except_result.py"))
               for f in fs)
    msgs = " | ".join(f.message for f in fs)
    assert "RoundResult" in msgs and "PathResult" in msgs
    assert "group_active" in msgs and "feat_active" in msgs
    # the clean handlers (rewind-then-build, star re-wrap) must NOT fire:
    # exactly the four seeded violations, nothing from the clean section
    assert len(fs) == 4


def test_cs004_fixture_stays_cs001_clean():
    """The CS004 fixture threads safety from names, so it must not leak
    into the CS001 counts (which other tests pin exactly)."""
    fs = cert_lint.lint_result_constructions(
        os.path.join(FIXTURES, "bad_src"))
    assert not any("except_result" in f.location for f in fs)


# ---------------------------------------------------------------------------
# 3. Pallas auditor fires on seeded launch geometry
# ---------------------------------------------------------------------------

def _spec1d(out_map, grid=(4,), nblocks=4, carried=(), name="fixture"):
    out = ArraySpec(shape=(nblocks * 8,), block=(8,), index_map=out_map)
    return LaunchSpec(name=name, grid=grid, inputs=(),
                      outputs=(out,), carried=(carried,))


def test_pl001_out_of_bounds_index():
    inp = ArraySpec(shape=(32,), block=(8,), index_map=lambda i: (i + 1,))
    spec = LaunchSpec(name="oob", grid=(4,), inputs=(inp,),
                      outputs=(ArraySpec((32,), (8,), lambda i: (i,)),))
    assert "PL001" in codes(pallas_audit.audit_launch_spec(spec))


def test_pl002_coverage_gap():
    # 8 output blocks, grid only writes the first 4
    out = ArraySpec(shape=(64,), block=(8,), index_map=lambda i: (i,))
    spec = LaunchSpec(name="gap", grid=(4,), inputs=(), outputs=(out,))
    fs = pallas_audit.audit_launch_spec(spec)
    assert "PL002" in codes(fs)


def test_pl003_overlapping_writes():
    fs = pallas_audit.audit_launch_spec(
        _spec1d(lambda i: (i // 2,), name="overlap"))
    assert "PL003" in codes(fs)


def test_pl004_vmem_budget():
    big = ArraySpec(shape=(4 * 2**20,), block=(4 * 2**20,),
                    index_map=lambda i: (0,))   # 32 MiB f64 tile
    out = ArraySpec(shape=(4,), block=(1,), index_map=lambda i: (i,))
    spec = LaunchSpec(name="huge", grid=(4,), inputs=(big,),
                      outputs=(out,), carried=((),))
    fs = pallas_audit.audit_launch_spec(spec)
    assert "PL004" in codes(fs)
    # a roomier budget accepts the same geometry
    fs = pallas_audit.audit_launch_spec(spec, vmem_budget=64 * 2**20)
    assert "PL004" not in codes(fs)


def test_pl005_carried_axis_actually_varies():
    # axis 0 declared carried but the map varies with it
    fs = pallas_audit.audit_launch_spec(
        _spec1d(lambda i: (i,), carried=(0,), name="bad-carry"))
    assert "PL005" in codes(fs)


def test_pl005_undeclared_invariant_axis():
    # output ignores grid axis 1 without declaring it carried
    out = ArraySpec(shape=(16,), block=(8,), index_map=lambda i, j: (i,))
    spec = LaunchSpec(name="undeclared", grid=(2, 3), inputs=(),
                      outputs=(out,), carried=((),))
    fs = pallas_audit.audit_launch_spec(spec)
    assert "PL005" in codes(fs)
    # declaring it carried makes the same geometry clean
    spec = LaunchSpec(name="declared", grid=(2, 3), inputs=(),
                      outputs=(out,), carried=((1,),))
    assert codes(pallas_audit.audit_launch_spec(spec)) == []


def test_pl000_broken_builder_is_a_finding():
    def boom():
        raise RuntimeError("no such config")

    fs = pallas_audit.run(audits={"broken": boom})
    assert codes(fs) == ["PL000"]


def test_pl006_subsampled_grid_is_reported():
    out = ArraySpec(shape=(10**6 * 8,), block=(8,),
                    index_map=lambda i: (i,))
    spec = LaunchSpec(name="big-grid", grid=(10**6,), inputs=(),
                      outputs=(out,), carried=((),))
    fs = pallas_audit.audit_launch_spec(spec, max_points=100)
    assert "PL006" in codes(fs, severity="info")
    assert codes(fs) == []   # corners in bounds; coverage proof skipped


# ---------------------------------------------------------------------------
# 4. Jaxpr lints fire on seeded entry points
# ---------------------------------------------------------------------------

def _spec(fn, *args, name="fixture", **meta):
    return EntryPointSpec(
        name=name, traceable=name,
        build=lambda: (fn, args, {}), **meta)


def test_jx001_dtype_demotion_fires():
    def demote(x):
        return x.astype(jnp.float32) * 2.0

    fs = jaxpr_lints.lint_entry_point(
        _spec(demote, jnp.ones(8, jnp.float64)))
    assert codes(fs) == ["JX001"]
    # the sanctioned min_float_bits=32 posture accepts the same program
    fs = jaxpr_lints.lint_entry_point(
        _spec(demote, jnp.ones(8, jnp.float64), min_float_bits=32))
    assert codes(fs) == []


def test_jx002_design_sized_transpose_fires():
    x = jnp.ones((8, 16), jnp.float64)

    fs = jaxpr_lints.lint_entry_point(
        _spec(jnp.transpose, x, design_elements=64))
    assert codes(fs) == ["JX002"]
    # small transposes (below the design size) stay legal
    fs = jaxpr_lints.lint_entry_point(
        _spec(jnp.transpose, x, design_elements=1024))
    assert codes(fs) == []
    # ... and the audited-path exemption is explicit
    fs = jaxpr_lints.lint_entry_point(
        _spec(jnp.transpose, x, design_elements=64,
              allow_design_transpose=True))
    assert codes(fs) == []


def test_jx003_design_sized_gather_fires():
    x = jnp.ones((16, 8), jnp.float64)
    idx = jnp.arange(16)

    def copy_via_take(x, idx):
        return jnp.take(x, idx, axis=0)

    fs = jaxpr_lints.lint_entry_point(
        _spec(copy_via_take, x, idx, design_elements=64))
    assert codes(fs) == ["JX003"]


def test_jx000_broken_template_is_a_finding():
    def bad_build():
        raise RuntimeError("template rotted")

    fs = jaxpr_lints.lint_entry_point(EntryPointSpec(
        name="broken", traceable="broken", build=bad_build))
    assert codes(fs) == ["JX000"]


def test_jx004_weak_type_retrace_fires():
    fn = jax.jit(lambda x, s: x * s)
    calls = {"n": 0}

    def build():
        calls["n"] += 1
        # first build: committed f64 scalar; second: weak-typed python
        # float — dtype-identical to the user, a fresh trace to jax
        s = jnp.float64(0.5) if calls["n"] == 1 else 0.5
        return fn, (jnp.ones(4, jnp.float64), s), {}

    with kops.audit_scope() as audit:
        fs = jaxpr_lints.retrace_harness(EntryPointSpec(
            name="weak-type", traceable="weak-type", build=build))
        assert codes(fs) == ["JX004"]
        assert audit.retraces >= 1   # observed retraces hit the counter


def test_jx004_stable_inputs_do_not_fire():
    fn = jax.jit(lambda x: x * 2.0)
    fs = jaxpr_lints.retrace_harness(_spec(fn, jnp.ones(4, jnp.float64)))
    assert codes(fs) == []


def test_jx005_unhashable_static_argument():
    fn = jax.jit(lambda xs: jnp.zeros(len(xs)), static_argnums=0)
    fs = jaxpr_lints.retrace_harness(_spec(fn, [1, 2, 3]))
    assert codes(fs) == ["JX005"]


def test_iter_eqns_walks_nested_jaxprs():
    def prog(x):
        def body(c, _):
            return jnp.sin(c), None

        y, _ = jax.lax.scan(body, x, None, length=3)
        return jax.jit(jnp.cos)(y)

    closed = jax.make_jaxpr(prog)(jnp.ones(4))
    prims = {e.primitive.name for e in jaxpr_lints.iter_eqns(closed.jaxpr)}
    assert "sin" in prims and "cos" in prims   # scan body + pjit body


# ---------------------------------------------------------------------------
# 5. Payload, renderer, CLI
# ---------------------------------------------------------------------------

def test_payload_shape_and_summary():
    fs = [Finding("cert", "CS001", "bad", severity="error"),
          Finding("pallas", "PL006", "info", severity="info")]
    payload = to_payload(fs, passes={"cert": {}, "pallas": {}})
    assert payload["schema"] == "repro.analysis/v1"
    assert payload["summary"] == {"errors": 1, "warnings": 0, "infos": 1}
    assert not payload["ok"]
    assert summarize([]) == {"errors": 0, "warnings": 0, "infos": 0}


def test_markdown_renderer_roundtrips_payload():
    from repro.launch.report import render_analysis_markdown

    bad = to_payload(
        [Finding("cert", "CS001", "a | pipe", location="x.py:1")],
        passes={"cert": {"findings": 1}})
    md = render_analysis_markdown(bad)
    assert "FAIL" in md and "CS001" in md and "a \\| pipe" in md
    ok = to_payload([], passes={"cert": {"findings": 0}})
    assert "PASS" in render_analysis_markdown(ok)


def test_cli_writes_artifacts_and_exit_code(tmp_path):
    from repro.analysis.__main__ import main

    rpt = tmp_path / "analysis.json"
    md = tmp_path / "analysis.md"
    rc = main(["--check", "--passes", "cert", "pallas",
               "--report", str(rpt), "--md", str(md)])
    assert rc == 0
    assert rpt.exists() and md.exists()
    import json

    payload = json.loads(rpt.read_text())
    assert payload["ok"] and payload["schema"] == "repro.analysis/v1"


# ---------------------------------------------------------------------------
# 6. audit_scope (satellite of this gate: scoped runtime counters)
# ---------------------------------------------------------------------------

def test_audit_scope_counts_and_restores():
    before_t = kops.transpose_trace_count()
    before_r = kops.retrace_count()
    with kops.audit_scope() as audit:
        assert audit.transpose_traces == 0
        kops.note_retrace(2)
        assert audit.retraces == 2
    # frozen after exit; globals restored to the surrounding values
    assert audit.retraces == 2
    kops.note_retrace()
    assert audit.retraces == 2
    assert kops.transpose_trace_count() == before_t
    assert kops.retrace_count() == before_r + 1
    kops.note_retrace(-1)   # keep the module counter as we found it


def test_audit_scope_restores_on_exception():
    t0 = kops.transpose_trace_count()
    with pytest.raises(RuntimeError):
        with kops.audit_scope():
            raise RuntimeError("boom")
    assert kops.transpose_trace_count() == t0


# ---------------------------------------------------------------------------
# 7. f64 posture (repro.core.precision)
# ---------------------------------------------------------------------------

def test_ensure_x64_enforced_by_core_import():
    from repro.core import ensure_x64

    assert ensure_x64() is True
    assert jax.config.read("jax_enable_x64")
    assert jnp.zeros(1).dtype == jnp.float64


def test_ensure_x64_escape_hatch(monkeypatch):
    from repro.core.precision import ensure_x64

    monkeypatch.setenv("REPRO_ALLOW_F32", "1")
    assert ensure_x64() is False   # explicitly waived, no error
