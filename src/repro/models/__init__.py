"""Model zoo dispatch: family -> implementation module."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

from . import encdec, rglru, ssm, transformer


class ModelAPI(NamedTuple):
    cfg: Any
    init_params: Callable
    param_specs: Callable      # (model_axis) -> spec tree
    forward: Callable          # (params, tokens, embeds=None) -> (logits, aux)
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    cache_specs: Callable


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
}


def build(cfg) -> ModelAPI:
    mod = _FAMILY_MODULES[cfg.family]
    return ModelAPI(
        cfg=cfg,
        init_params=functools.partial(mod.init_params, cfg),
        param_specs=functools.partial(mod.param_specs, cfg),
        forward=functools.partial(mod.forward, cfg),
        prefill=functools.partial(mod.prefill, cfg),
        decode_step=functools.partial(mod.decode_step, cfg),
        init_cache=functools.partial(mod.init_cache, cfg),
        cache_specs=functools.partial(mod.cache_specs, cfg),
    )


__all__ = ["build", "ModelAPI", "transformer", "ssm", "rglru", "encdec"]
