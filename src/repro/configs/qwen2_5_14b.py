"""qwen2.5-14b — dense GQA decoder, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5_120,
    n_heads=40,
    n_kv=8,
    d_ff=13_824,
    vocab=152_064,
    qkv_bias=True,
    subquadratic=False,
    notes="GQA kv=8, QKV bias",
)
