"""Jitted dispatch wrappers for the Pallas kernels.

Handles padding to TPU-aligned block shapes and exposes the kernels with the
grouped-layout signatures the solver uses.  Interpret-vs-compile policy lives
in kernels/_util.py (the kernel entry points default to it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dual_norm import dual_norm_pallas
from .screening_scores import screening_scores_pallas
from .sgl_prox import sgl_prox_pallas


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("tau", "lam", "block_g"))
def sgl_prox(beta, step, w, tau: float, lam: float, block_g: int = 256):
    """Fused two-level prox; beta (G, ng), step/w (G,). Any G, ng."""
    G, ng = beta.shape
    bg = min(block_g, max(8, G))
    b = _pad_to(beta, 0, bg)
    s = _pad_to(step, 0, bg, value=1.0)
    ww = _pad_to(w, 0, bg, value=1.0)
    out = sgl_prox_pallas(b, s, ww, tau, lam, block_g=bg)
    return out[:G]


@functools.partial(jax.jit, static_argnames=("n_iter", "block_g"))
def dual_norm_groups(x, alpha, R, n_iter: int = 64, block_g: int = 256):
    """Per-group Lambda(x_g, alpha_g, R_g); x (G, ng), alpha/R (G,) -> (G,)."""
    G, ng = x.shape
    bg = min(block_g, max(8, G))
    xp = _pad_to(x, 0, bg)
    ap = _pad_to(alpha, 0, bg, value=1.0)
    Rp = _pad_to(R, 0, bg, value=1.0)
    out = dual_norm_pallas(xp, ap, Rp, n_iter=n_iter, block_g=bg)
    return out[:G]


@functools.partial(jax.jit, static_argnames=("tau", "block_p", "block_n"))
def screening_scores(Xt, theta, tau: float, block_p: int = 256,
                     block_n: int = 128):
    """Fused corr = X^T theta and S_tau(corr)^2; Xt (p, n), theta (n,)."""
    p, n = Xt.shape
    bp = min(block_p, max(8, p))
    bn = min(block_n, max(8, n))
    Xp = _pad_to(_pad_to(Xt, 0, bp), 1, bn)
    tp = _pad_to(theta, 0, bn)
    corr, st2 = screening_scores_pallas(
        Xp, tp, tau, block_p=bp, block_n=bn
    )
    return corr[:p], st2[:p]


def screening_corr_grouped(X: jax.Array, v: jax.Array) -> jax.Array:
    """Grouped correlation X^T v via the fused Pallas matvec kernel.

    X (n, G, ng) zero-padded grouped design, v (n,) -> (G, ng).  Padded
    feature columns are zero in X, so their correlations come out zero and
    stay inert downstream — same contract as the einsum path.  This is the
    hot half of the solver's certified screening round (solver.screen_round
    with backend="pallas").
    """
    n, G, ng = X.shape
    Xt = X.reshape(n, G * ng).T                        # (p, n), free reshape
    corr, _ = screening_scores(Xt, v, tau=0.0)         # st2 unused here
    return corr.reshape(G, ng)


def sgl_dual_norm_fused(corr_grouped, tau, w, n_iter: int = 64):
    """Omega^D via the Pallas bisection kernel (drop-in for sgl.sgl_dual_norm)."""
    from repro.core.sgl import epsilons, group_weight_total

    eps = epsilons(tau, w)
    scale = group_weight_total(tau, w)
    per_group = dual_norm_groups(corr_grouped, 1.0 - eps, eps, n_iter=n_iter)
    return jnp.max(per_group / scale)


def sgl_prox_batched(beta, lam_b, L, w, tau: float, block_g: int = 256):
    """Two-level prox over a batched-lambda state (B, G, ng).

    Each (b, g) row is an independent prox at threshold lam_b / L — exactly
    the per-row layout ``sgl_prox_pallas`` tiles, so the batched case
    reuses the same kernel on the flattened (B*G, ng) view. This is the
    prox step of the batched-lambda FISTA kernel (EXPERIMENTS.md §Perf,
    sgl-paper iterations 3-4).
    """
    B, G, ng = beta.shape
    flat = beta.reshape(B * G, ng)
    step = jnp.broadcast_to((lam_b / L)[:, None], (B, G)).reshape(-1)
    w_flat = jnp.broadcast_to(w[None, :], (B, G)).reshape(-1)
    bg = min(block_g, max(8, B * G))
    b = _pad_to(flat, 0, bg)
    s = _pad_to(step, 0, bg, value=1.0)
    ww = _pad_to(w_flat, 0, bg, value=1.0)
    out = sgl_prox_pallas(b, s, ww, tau, 1.0, block_g=bg)
    return out[: B * G].reshape(B, G, ng)
