"""The serve loop: queue -> coalesce -> cached session -> warm solve.

:class:`SGLServer` owns one worker thread and four pieces of state — a
:class:`repro.serve.queue.RequestQueue`, a
:class:`repro.serve.cache.SessionCache`, a
:class:`repro.serve.store.CertificateStore`, and (optionally) a
checkpoint directory — and turns tenant :class:`PathRequest`\\ s into
:class:`PathResponse`\\ s:

1. drained requests coalesce by value (identical requests collapse into
   one solve; ``merge_grids`` additionally unions same-problem grids);
2. the session cache supplies a jit-warm :class:`SGLSession` (per-request
   solver caches are reset, so a cached session's trajectory is
   bit-identical to a fresh one — the coalescing parity guarantee);
3. the certificate store short-circuits exact repeats and offers primal
   warm-start hints for perturbed-``y`` / refined-grid re-solves —
   admitted only when :func:`repro.serve.store.warm_eval` measures the
   hint's gap beating the cold start's, and NEVER as certificates (every
   reported discard comes from a fresh GAP round inside the solve);
   merged-grid slices seed warm-start records only, never the
   exact-repeat map, whose contract is the solo solve's output verbatim;
4. with checkpointing enabled, paths run in ``ckpt_every``-lambda
   segments through the atomic :mod:`repro.ckpt` writer; a drain (or
   SIGTERM via :meth:`install_sigterm_hook`) checkpoints at the next
   segment boundary and fails in-flight futures with :class:`Preempted`,
   and a re-submitted request on a restarted server resumes from the
   stored cursor — bit-identical to an uninterrupted run with the same
   segmenting (`solve_path`'s ``beta0``/``prev_epochs`` threading).
   Resume is guarded by the manifest's request digest, solver-cache
   digest, AND a digest of the grid actually solved, so a union-grid
   checkpoint left by a merged group is never adopted by a solo
   re-submission of its lead request.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import signal
import threading
import time
from typing import Callable, List, Optional

import numpy as np
import jax.numpy as jnp

from .. import ckpt
from ..core.session import PathResult, SGLSession, SolverConfig
from ..core.solver import SolveCaches
from ..faults.budget import SolveBudget
from ..faults.errors import Degraded, ServeError, WorkerCrash
from ..faults.inject import maybe_kill
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .cache import SessionCache
from .queue import CoalescedGroup, Pending, RequestQueue, coalesce
from .store import CertificateStore, warm_eval
from .types import PathRequest, PathResponse, array_digest, problem_digest

__all__ = ["ServeConfig", "SGLServer", "Preempted"]

# Serve counters, declared once with help text (repro.obs --check OB001
# audits this table).  SGLServer.counters is a CounterMap shim over these
# in a per-server registry, keeping the legacy dict surface intact.
_SERVE_COUNTERS = {
    "requests": "Tenant requests submitted",
    "responses": "Futures resolved with a PathResponse",
    "path_solves": "Actual path solves run (store hits excluded)",
    "coalesced_requests": "Requests served by a shared coalesced solve",
    "store_served": "Requests short-circuited by an exact store repeat",
    "warm_started": "Requests whose solve adopted a measured warm hint",
    "resumed": "Paths resumed from a checkpoint cursor",
    "preempted": "Requests failed with Preempted during a drain",
    "worker_restarts": "Supervisor restarts of a crashed worker loop",
    "retries": "Serve-side retries of a failed group",
    "degraded": "Requests resolved with a typed Degraded",
    "failed": "Requests failed terminally after retry exhaustion",
    "breaker_rejections": "Requests fast-failed by an open circuit breaker",
}
for _k, _h in _SERVE_COUNTERS.items():
    obs_metrics.declare("serve." + _k, "counter", _h)
obs_metrics.declare(
    "serve.queue_wait_s", "histogram",
    "Per-member wait between submit and the worker picking the group up")


class Preempted(RuntimeError):
    """The server drained (shutdown/SIGTERM) before this request finished.

    ``cursor`` is the lambda index the path had reached (checkpointed
    when the server runs with a ckpt dir); resubmitting the identical
    request to a restarted server resumes there.
    """

    def __init__(self, request_digest: str, cursor: int):
        super().__init__(
            f"request {request_digest} preempted at lambda index {cursor}"
        )
        self.request_digest = request_digest
        self.cursor = cursor


@dataclasses.dataclass
class ServeConfig:
    """Serving knobs (solver knobs live in ``default_solver``)."""

    default_solver: SolverConfig = dataclasses.field(
        default_factory=SolverConfig)
    coalesce: bool = True            # False: every request solves alone
    merge_grids: bool = False        # union-grid merging (tol-level parity)
    coalesce_window_s: float = 0.02  # drain window after the first request
    max_batch: int = 32              # requests per drain
    warm_start: bool = True          # certificate-store primal hints
    serve_from_store: bool = True    # exact-repeat short-circuit
    session_capacity: int = 8        # LRU sessions (0 disables caching)
    store_capacity: int = 32         # LRU stored paths (0 disables)
    batch_lambdas: int = 4           # forwarded to solve_path
    ckpt_dir: Optional[str] = None   # enables resumable paths
    ckpt_every: int = 0              # lambdas per segment (0: no chunking)
    ckpt_keep: int = 3               # keep-k GC per request dir
    on_segment: Optional[Callable[[str, int, int], None]] = None
                                     # (digest, cursor, T) after each
                                     # segment — observability/test hook
    # -- graceful degradation (repro.faults) -------------------------------
    deadline_s: Optional[float] = None   # per-request wall-clock budget;
                                         #   a trip resolves the future
                                         #   with a typed Degraded carrying
                                         #   the certified prefix
    epoch_budget: Optional[int] = None   # per-request total-epoch cap
    max_retries: int = 2             # serve-side retries for transient
                                     #   failures (crashes, raised solves)
    retry_backoff_s: float = 0.05    # exponential backoff base between
                                     #   retries of one group
    breaker_threshold: int = 3       # consecutive terminal failures on one
                                     #   problem before its breaker opens
    breaker_cooldown_s: float = 30.0 # how long an open breaker fast-fails
                                     #   new requests for that problem


class SGLServer:
    """Multi-tenant path-solve server over one worker thread."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = config if config is not None else ServeConfig()
        self.queue = RequestQueue()
        self.cache = SessionCache(capacity=self.config.session_capacity)
        self.store = CertificateStore(capacity=self.config.store_capacity)
        self._drain = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._served: set = set()      # digests completed at least once
        self._lock = threading.Lock()
        # In-flight coalesced groups: ``[group, attempts]`` entries the
        # worker is retrying.  Owned by the worker thread (the supervisor
        # restart re-enters _worker_loop on the same thread), so a crashed
        # solve loop never loses a queued future — every entry is served
        # to a terminal outcome (result, Degraded, Preempted, ServeError).
        self._inflight: List[list] = []
        # Per-problem circuit breaker: problem digest -> [consecutive
        # terminal failures, open-until monotonic timestamp].
        self._breaker: dict = {}
        self._sigterm_installed = False
        self._sigterm_prev = None
        # Per-server metrics registry under the shared declared names:
        # several servers in one process (bench baselines) keep separate
        # numbers.  `counters` is the historical dict surface, now a shim.
        self.metrics = obs_metrics.MetricsRegistry()
        self.counters = obs_metrics.CounterMap(
            self.metrics, "serve.", _SERVE_COUNTERS)
        self._m_queue_wait = self.metrics.histogram("serve.queue_wait_s")

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "SGLServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(target=self._worker,
                                        name="sgl-serve", daemon=True)
        self._thread.start()
        return self

    def submit(self, request: PathRequest):
        """Enqueue one tenant request; returns a Future[PathResponse]."""
        fut = self.queue.submit(request, self.config.default_solver)
        with self._lock:     # tenants submit from arbitrary threads
            self.counters["requests"] += 1
        return fut

    def stop(self, timeout: Optional[float] = None) -> None:
        """Finish everything queued, then stop the worker."""
        self.queue.close()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def drain(self) -> None:
        """Preemption path: stop accepting work, checkpoint in-flight
        paths at the next segment boundary, fail their futures with
        :class:`Preempted`.  Safe to call from a signal handler."""
        self._drain.set()
        self.queue.close()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def install_sigterm_hook(self):
        """Route SIGTERM (pod preemption) to :meth:`drain`; returns the
        previous handler so callers/tests can restore it.

        Idempotent (a second install is a no-op returning the same
        previous handler) and chaining (a pre-existing callable handler
        runs after the drain).  :meth:`drain` itself only sets events, so
        a second SIGTERM landing mid-drain is harmless — the checkpoint
        write happens at the worker's segment boundary, never here.
        """
        if self._sigterm_installed:
            return self._sigterm_prev
        prev = signal.getsignal(signal.SIGTERM)

        def handler(signum, frame):
            self.drain()
            if callable(prev):
                prev(signum, frame)

        signal.signal(signal.SIGTERM, handler)
        self._sigterm_installed = True
        self._sigterm_prev = prev
        return prev

    @property
    def draining(self) -> bool:
        return self._drain.is_set()

    def stats(self) -> dict:
        return {
            **self.counters,
            "cache": self.cache.stats(),
            "store": self.store.stats(),
            "queue_submitted": self.queue.submitted,
        }

    # -- worker ------------------------------------------------------------

    def _worker(self) -> None:
        """Supervisor: restart a crashed solve loop without losing queued
        futures.  A :class:`WorkerCrash` (or any escaping exception)
        tears down :meth:`_worker_loop`; the in-flight entry stays in
        ``self._inflight`` with its attempt count bumped, so the restarted
        loop retries it (bounded by ``max_retries``) before draining new
        work — no future is ever left forever-pending."""
        while True:
            try:
                self._worker_loop()
                return
            except Exception:
                self.counters["worker_restarts"] += 1

    def _worker_loop(self) -> None:
        cfg = self.config
        while True:
            while self._inflight:
                if self._serve_entry(self._inflight[0]):
                    self._inflight.pop(0)
            pending = self.queue.drain(max_batch=cfg.max_batch,
                                       window_s=cfg.coalesce_window_s)
            if pending is None:
                return
            if self._drain.is_set():
                self._fail(pending, cursor=0)
                continue
            with obs_trace.span("serve.coalesce") as sp:
                if cfg.coalesce:
                    groups = coalesce(pending, cfg.default_solver,
                                      merge_grids=cfg.merge_grids)
                else:
                    groups = [
                        CoalescedGroup(
                            members=[p], lambdas=p.request.grid(),
                            member_index=[np.arange(len(p.request.grid()))],
                            merged=False,
                        )
                        for p in pending
                    ]
                sp.set("pending", len(pending)).set("groups", len(groups))
            self._inflight.extend([g, 0] for g in groups)

    def _serve_entry(self, entry: list) -> bool:
        """Serve one in-flight group to a terminal outcome or a retry.

        Returns True when the entry is finished (every member future
        resolved — with a result, Degraded, Preempted, or ServeError) and
        False when it should be retried by the caller.  A WorkerCrash
        re-raises to the supervisor AFTER bumping the attempt count, so
        the restarted loop picks the same entry back up.
        """
        cfg = self.config
        group, attempts = entry[0], entry[1]
        members = [p for p in group.members if not p.future.done()]
        if not members:
            return True
        if self._drain.is_set():
            self._fail(members, cursor=0)
            return True
        key = self._breaker_key(group)
        if self._breaker_open(key):
            self.counters["breaker_rejections"] += len(members)
            for p in members:
                p.future.set_exception(ServeError(
                    "circuit breaker open for this problem "
                    f"(cooldown {cfg.breaker_cooldown_s:g}s)",
                    request_digest=p.digest,
                ))
            return True
        try:
            maybe_kill("serve.worker")
            self._serve_group(group)
        except Preempted as e:
            self.counters["preempted"] += len(members)
            for p in members:
                if not p.future.done():
                    p.future.set_exception(Preempted(p.digest, e.cursor))
            return True
        except Degraded as e:
            # A budget trip is a terminal, typed, honest outcome — not a
            # failure: the breaker does not count it.
            self.counters["degraded"] += len(members)
            for p in members:
                if not p.future.done():
                    p.future.set_exception(e)
            return True
        except Exception as e:
            entry[1] = attempts = attempts + 1
            if attempts > cfg.max_retries:
                self._breaker_fail(key)
                self.counters["failed"] += len(members)
                err = e if isinstance(e, ServeError) else ServeError(
                    f"retries exhausted after {attempts} attempts: {e!r}",
                    request_digest=group.members[0].digest, cause=e,
                )
                for p in members:
                    if not p.future.done():
                        p.future.set_exception(err)
                return True
            self.counters["retries"] += 1
            if isinstance(e, WorkerCrash):
                raise          # supervisor restarts the loop; entry kept
            time.sleep(cfg.retry_backoff_s * (2 ** (attempts - 1)))
            return False
        self._breaker.pop(key, None)
        return True

    # -- circuit breaker ----------------------------------------------------

    def _breaker_key(self, group: CoalescedGroup) -> str:
        req = group.members[0].request
        scfg = req.resolved_config(self.config.default_solver)
        return problem_digest(req.problem, scfg)

    def _breaker_open(self, key: str) -> bool:
        st = self._breaker.get(key)
        return (st is not None
                and st[0] >= self.config.breaker_threshold
                and time.monotonic() < st[1])

    def _breaker_fail(self, key: str) -> None:
        st = self._breaker.setdefault(key, [0, 0.0])
        st[0] += 1
        if st[0] >= self.config.breaker_threshold:
            st[1] = time.monotonic() + self.config.breaker_cooldown_s

    def _fail(self, members: List[Pending], cursor: int) -> None:
        self.counters["preempted"] += len(members)
        for p in members:
            if not p.future.done():
                p.future.set_exception(Preempted(p.digest, cursor))

    # -- serving one coalesced group ----------------------------------------

    def _serve_group(self, group: CoalescedGroup) -> None:
        with obs_trace.span("serve.request") as sp:
            sp.set("members", len(group.members))
            self._serve_group_impl(group)

    def _serve_group_impl(self, group: CoalescedGroup) -> None:
        cfg = self.config
        t_start = time.perf_counter()
        lead = group.members[0]
        req = lead.request
        scfg = req.resolved_config(cfg.default_solver)
        digest = lead.digest

        # Exact-repeat short-circuit: the stored result of an identical
        # request (problem + grid + config values) is the solve's output
        # verbatim — served from memory, zero solver work.
        if cfg.serve_from_store and not group.merged:
            with obs_trace.span("serve.store"):
                stored = self.store.exact(digest)
            if stored is not None:
                self.counters["store_served"] += len(group.members)
                self._respond(group, stored, served_from="store",
                              store_hit=True, t_start=t_start)
                return

        with obs_trace.span("serve.cache"):
            session, hit = self.cache.get(req.problem, scfg)
        # Per-request solver caches: a cached session must produce the
        # exact trajectory a fresh one would (coalesced-vs-solo parity),
        # so cross-request gather/reference state never leaks in.
        session.caches = SolveCaches()

        beta0 = None
        warm_started = False
        warm_lam = None
        if cfg.warm_start and req.warm_start and self.store.capacity > 0:
            hint = self.store.warm_hint(req.problem, scfg, group.lambdas)
            if hint is not None:
                dtype = req.problem.X.dtype
                lam0 = jnp.asarray(float(group.lambdas[0]), dtype)
                beta_h = jnp.asarray(hint.beta, dtype)
                # The admission gap is evaluated under the REQUEST's loss
                # (loss=None is the squared loss, sharing the historical
                # jit program): a hint must beat the cold start on the
                # data fidelity actually being solved.
                wloss = (None if session.loss.name == "lsq"
                         else session.loss)
                with obs_trace.span("serve.warm_eval"):
                    gap_h = float(warm_eval(req.problem, beta_h, lam0,
                                            loss=wloss))
                    gap_c = float(warm_eval(
                        req.problem, jnp.zeros_like(beta_h), lam0,
                        loss=wloss))
                # Admission is measured: adopt the hint only when its gap
                # on the NEW problem beats the cold start's.  The hint is
                # a primal point only — solve_path re-screens it with a
                # fresh GAP round before any epoch, so stored certificates
                # are never reused (see repro.serve.store).
                if np.isfinite(gap_h) and gap_h < gap_c:
                    beta0 = beta_h
                    warm_started = True
                    warm_lam = hint.lam_src
                    self.counters["warm_started"] += len(group.members)

        # Retrace watch (cache correctness): an exact repeat of a request
        # this server already solved, served from a session-cache hit,
        # must not grow any jit cache — measured, and fed to the
        # kernels.ops audit so tests can assert it via audit_scope().
        watch = (self.cache.watch_retraces()
                 if hit and digest in self._served
                 else contextlib.nullcontext())
        # Per-request budget: attached for the duration of this solve
        # only (the session is shared across requests via the cache).
        if cfg.deadline_s is not None or cfg.epoch_budget is not None:
            session.budget = SolveBudget(cfg.deadline_s, cfg.epoch_budget)
        try:
            with watch:
                result, resumed_from = self._run_path(
                    session, scfg, group.lambdas, beta0, digest
                )
        finally:
            session.budget = None
        if result.degraded:
            # Typed, honest degradation: the truncated prefix rides on the
            # error with the last certified full-problem gap.  Raised
            # BEFORE _respond, so a degraded result is never stored as an
            # exact repeat and never warm-seeds the store.
            gap_last = (float(result.gaps[-1]) if len(result.gaps)
                        else float("inf"))
            raise Degraded(result, result.degraded, gap_last)
        self.counters["path_solves"] += 1
        if len(group.members) > 1:
            self.counters["coalesced_requests"] += len(group.members)
        if resumed_from:
            self.counters["resumed"] += 1
        with self._lock:
            self._served.add(digest)

        self._respond(
            group, result,
            served_from="coalesced" if len(group.members) > 1 else "solve",
            session_cache_hit=hit, warm_started=warm_started,
            warm_source_lam=warm_lam, resumed_from=resumed_from,
            t_start=t_start, solve_s=time.perf_counter() - t_start,
        )

    def _respond(self, group: CoalescedGroup, result: PathResult, *,
                 served_from: str, t_start: float,
                 session_cache_hit: bool = False, store_hit: bool = False,
                 warm_started: bool = False,
                 warm_source_lam: Optional[float] = None,
                 resumed_from: Optional[int] = None,
                 solve_s: float = 0.0) -> None:
        cfg = self.config
        for p, idx in zip(group.members, group.member_index):
            member_res = (result if not group.merged
                          else _slice_result(result, idx))
            if served_from != "store" and cfg.serve_from_store:
                scfg = p.request.resolved_config(cfg.default_solver)
                # A merged-grid slice agrees with the request's solo run
                # only to solver tolerance, so it may seed warm-start
                # records but never the exact-repeat map — a later
                # identical solo request must get the verbatim guarantee
                # the store promises, not a tolerance-level stand-in.
                with obs_trace.span("serve.store"):
                    self.store.put(p.digest, p.request.problem, scfg,
                                   member_res, exact=not group.merged)
            if p.future.done():     # resolved by an earlier attempt/drain
                continue
            self._m_queue_wait.observe(t_start - p.t_submit)
            self.counters["responses"] += 1
            p.future.set_result(PathResponse(
                tenant=p.request.tenant,
                request_digest=p.digest,
                result=member_res,
                served_from=served_from,
                coalesced_n=len(group.members),
                session_cache_hit=session_cache_hit,
                store_hit=store_hit,
                warm_started=warm_started,
                warm_source_lam=warm_source_lam,
                resumed_from=resumed_from,
                merged_grid=group.merged,
                queue_s=t_start - p.t_submit,
                solve_s=solve_s,
            ))

    # -- the (optionally resumable) path runner ------------------------------

    def _run_path(self, session: SGLSession, scfg: SolverConfig,
                  lambdas: np.ndarray, beta0, digest: str):
        """Run one path, in ``ckpt_every``-lambda segments when
        checkpointing is on; returns ``(PathResult, resumed_from)``."""
        cfg = self.config
        T_ = len(lambdas)
        chunked = cfg.ckpt_dir is not None and cfg.ckpt_every > 0
        if not chunked:
            if self.draining:
                raise Preempted(digest, 0)
            res = session.solve_path(
                lambdas, beta0=beta0, batch_lambdas=cfg.batch_lambdas,
            )
            return res, None

        rdir = os.path.join(cfg.ckpt_dir, digest)
        caches_dig = hashlib.blake2b(
            repr(self.cache.key(session.problem, scfg)).encode(),
            digest_size=8,
        ).hexdigest()
        # Identity of the grid actually being solved.  The request digest
        # alone is not enough: a merged group checkpoints under the lead
        # member's digest but solves the UNION grid, so a later solo
        # re-submission of the lead request (same digest, different grid)
        # must not adopt that checkpoint — its prefix arrays belong to
        # union lambda points.  Verified on resume below.
        grid_dig = array_digest(lambdas)
        cursor = 0
        prev_epochs = 0
        beta_carry = beta0
        segments: List[PathResult] = []
        acc = None              # restored pre-preemption state, if any
        resumed_from = None
        rule_restored = None    # rule_name when resuming a complete path

        found = ckpt.latest(rdir)
        if found is not None:
            step, manifest = found
            extra = manifest.get("extra", {})
            if (extra.get("request") == digest
                    and extra.get("grid") == grid_dig
                    and extra.get("caches") == caches_dig
                    and 0 < int(extra.get("cursor", 0)) <= T_):
                tree_like = {
                    k: np.zeros(spec["shape"], np.dtype(spec["dtype"]))
                    for k, spec in manifest["leaves"].items()
                }
                acc = ckpt.restore(rdir, tree_like, step=step)
                cursor = int(extra["cursor"])
                prev_epochs = int(extra.get("prev_epochs", 0))
                beta_carry = jnp.asarray(acc["beta_carry"],
                                         session.problem.X.dtype)
                resumed_from = cursor
                rule_restored = extra.get("rule_name")

        degraded = ""
        while cursor < T_:
            if self.draining:
                raise Preempted(digest, cursor)
            # Chaos hook: a worker kill mid-path (between segments) —
            # recovery resumes from the last intact checkpoint.
            maybe_kill("serve.segment")
            # Fresh per-segment solver caches: a resumed run starts its
            # segment with empty caches, so the continuous run must too —
            # that is what makes interrupted+resumed bit-identical to
            # uninterrupted (with the same segmenting).
            session.caches = SolveCaches()
            sub = lambdas[cursor:cursor + cfg.ckpt_every]
            pr = session.solve_path(
                sub, beta0=beta_carry,
                prev_epochs=prev_epochs or None,
                batch_lambdas=cfg.batch_lambdas,
            )
            segments.append(pr)
            # A degraded segment solved only a prefix of its sub-grid; the
            # cursor advances by what was actually certified.
            cursor += len(pr.lambdas)
            if len(pr.lambdas):
                prev_epochs = int(pr.epochs[-1])
                beta_carry = jnp.asarray(pr.betas[-1],
                                         session.problem.X.dtype)
                state = _pack_state(acc, segments, beta_carry)
                ckpt.save(rdir, cursor, state, extra_manifest={
                    "request": digest,
                    "grid": grid_dig,
                    "cursor": cursor,
                    "prev_epochs": prev_epochs,
                    "caches": caches_dig,
                    "rule_name": pr.rule_name,
                    "T": T_,
                })
                ckpt.gc_keep_k(rdir, cfg.ckpt_keep)
                if cfg.on_segment is not None:
                    cfg.on_segment(digest, cursor, T_)
            if pr.degraded:
                degraded = pr.degraded
                break

        lam_out = lambdas[:cursor] if degraded else lambdas
        return (_assemble(lam_out, acc, segments, rule_restored,
                          degraded=degraded),
                resumed_from)


# ----------------------------------------------------------------------------
# Segment bookkeeping: pack/accumulate/stitch PathResult state
# ----------------------------------------------------------------------------

_ARRAY_FIELDS = ("betas", "gaps", "epochs", "group_active_frac",
                 "feat_active_frac", "group_active", "feat_active",
                 "seq_screened", "dyn_screened")
_SUM_FIELDS = ("n_rounds", "n_transpose_copies", "n_compact_rounds",
               "n_full_rounds", "round_flops", "n_fused_epoch_launches",
               "batched_lambdas", "n_gathers")


def _pack_state(acc, segments: List[PathResult], beta_carry) -> dict:
    """Flat checkpoint tree: solved-prefix arrays + counters + carry."""
    state: dict = {}
    for f in _ARRAY_FIELDS:
        parts = ([acc[f]] if acc is not None else []) \
            + [np.asarray(getattr(s, f)) for s in segments]
        state[f] = np.concatenate(parts, axis=0)
    for f in _SUM_FIELDS:
        prior = float(acc[f]) if acc is not None else 0.0
        state[f] = np.asarray(
            prior + sum(float(getattr(s, f)) for s in segments))
    safe_prior = bool(acc["certificates_safe"]) if acc is not None else True
    state["certificates_safe"] = np.asarray(
        safe_prior and all(bool(s.certificates_safe) for s in segments))
    state["beta_carry"] = np.asarray(beta_carry)
    return state


def _assemble(lambdas: np.ndarray, acc,
              segments: List[PathResult],
              rule_restored: Optional[str] = None,
              degraded: str = "") -> PathResult:
    """Stitch restored state + fresh segments into one PathResult.

    ``rule_restored`` is the rule_name persisted in the checkpoint
    manifest — the only rule source when resume finds a fully-complete
    checkpoint (no fresh segments ran)."""
    state = _pack_state(acc, segments, np.zeros(0))
    counters = {f: (float(state[f]) if f == "round_flops"
                    else int(state[f])) for f in _SUM_FIELDS}
    rule_name = (segments[-1].rule_name if segments
                 else rule_restored if rule_restored is not None
                 else "gap")
    return PathResult(
        lambdas=np.asarray(lambdas, float),
        betas=state["betas"],
        gaps=state["gaps"],
        epochs=state["epochs"],
        group_active_frac=state["group_active_frac"],
        feat_active_frac=state["feat_active_frac"],
        group_active=state["group_active"],
        feat_active=state["feat_active"],
        seq_screened=state["seq_screened"],
        dyn_screened=state["dyn_screened"],
        n_gathers=counters["n_gathers"],
        results=[],
        n_rounds=counters["n_rounds"],
        n_transpose_copies=counters["n_transpose_copies"],
        n_compact_rounds=counters["n_compact_rounds"],
        n_full_rounds=counters["n_full_rounds"],
        round_flops=counters["round_flops"],
        n_fused_epoch_launches=counters["n_fused_epoch_launches"],
        batched_lambdas=counters["batched_lambdas"],
        rule_name=rule_name,
        certificates_safe=bool(state["certificates_safe"]),
        degraded=degraded,
    )


def _slice_result(result: PathResult, idx: np.ndarray) -> PathResult:
    """A member's view of a merged-grid solve: its own grid points sliced
    out of the union path.  Solve counters are those of the shared union
    run (one solve served several tenants — per-member attribution would
    be fiction)."""
    return PathResult(
        lambdas=np.asarray(result.lambdas)[idx],
        betas=np.asarray(result.betas)[idx],
        gaps=np.asarray(result.gaps)[idx],
        epochs=np.asarray(result.epochs)[idx],
        group_active_frac=np.asarray(result.group_active_frac)[idx],
        feat_active_frac=np.asarray(result.feat_active_frac)[idx],
        group_active=np.asarray(result.group_active)[idx],
        feat_active=np.asarray(result.feat_active)[idx],
        seq_screened=np.asarray(result.seq_screened)[idx],
        dyn_screened=np.asarray(result.dyn_screened)[idx],
        n_gathers=result.n_gathers,
        results=[],
        n_rounds=result.n_rounds,
        n_transpose_copies=result.n_transpose_copies,
        n_compact_rounds=result.n_compact_rounds,
        n_full_rounds=result.n_full_rounds,
        round_flops=result.round_flops,
        n_fused_epoch_launches=result.n_fused_epoch_launches,
        batched_lambdas=result.batched_lambdas,
        rule_name=result.rule_name,
        certificates_safe=result.certificates_safe,
        degraded=result.degraded,
    )
