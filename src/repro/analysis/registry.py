"""Leaf registries wiring the codebase into the static analyzer.

This module imports NOTHING from :mod:`repro` (and nothing heavy at all),
so the hook sites can register themselves at import time without cycles:

* :func:`register_traceable` — called at the bottom of
  ``repro/core/solver.py`` / ``repro/core/session.py`` to expose their
  jitted entry points (the objects whose jaxprs the lints walk and whose
  jit caches the retrace harness watches).  The analyzer pairs each
  registered name with a shape/dtype template in
  :mod:`repro.analysis.entrypoints`; a registered traceable without a
  template (or vice versa) is itself a finding, so a new entry point
  cannot silently escape the gate.
* :func:`register_kernel_audit` — called at the bottom of
  ``repro/kernels/ops.py`` with zero-argument builders returning the
  :class:`repro.kernels._util.LaunchSpec` for representative configs; the
  Pallas auditor (:mod:`repro.analysis.pallas_audit`) evaluates every
  registered spec.

Registration is idempotent by name (last wins) so re-imports under test
runners never trip a duplicate guard.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

__all__ = [
    "kernel_audits",
    "register_kernel_audit",
    "register_traceable",
    "traceables",
]

_TRACEABLES: Dict[str, Dict[str, Any]] = {}
_KERNEL_AUDITS: Dict[str, Callable[[], Any]] = {}


def register_traceable(name: str, fn: Callable, **meta: Any) -> Callable:
    """Expose a jitted entry point to the jaxpr lints under ``name``.

    ``fn`` must be the *jitted* object actually dispatched at runtime (not
    a re-wrap), so the retrace harness measures the real cache.  ``meta``
    is free-form context surfaced in findings (e.g. ``module=``).
    """
    _TRACEABLES[name] = {"fn": fn, **meta}
    return fn


def traceables() -> Dict[str, Dict[str, Any]]:
    return dict(_TRACEABLES)


def register_kernel_audit(name: str,
                          builder: Callable[[], Any]) -> Callable[[], Any]:
    """Register a zero-argument LaunchSpec builder for the Pallas auditor.

    The builder should return the launch geometry for a *representative*
    config (shapes a real solve would use); over-budget or ill-covered
    geometry fails the gate before it can OOM or corrupt at runtime.
    """
    _KERNEL_AUDITS[name] = builder
    return builder


def kernel_audits() -> Dict[str, Callable[[], Any]]:
    return dict(_KERNEL_AUDITS)
